"""Pallas preflight: every zoo shape checked against the kernel contracts.

The three `kernels/` trees each export a `preflight()` that mirrors their
wrapper's padding/tiling logic without launching anything; this check maps
a target's workload shapes through them and converts the results into
findings — BEFORE the first interpret-mode fallback ever hides a shape
that would fault on real hardware.

Findings:

  PAL001 ERROR    estimated VMEM working set exceeds the ~16 MiB/core
                  budget: the kernel cannot stage its blocks on chip
  PAL002 WARNING  padding waste > 50%: the shape is legal but a large
                  share of the MACs multiply zeros — re-block or re-shape
  PAL003 ERROR    hard contract violation (a block/lane divisibility the
                  MXU/VPU tiling cannot accept); soft issues (lane dims
                  the compiler pads at a lane-utilization cost) downgrade
                  to WARNING
"""

from __future__ import annotations

from repro.analysis.findings import Finding, Severity
from repro.analysis.registry import register
from repro.analysis.target import AnalysisTarget

# ~16 MB/core of VMEM (see /opt/skills/guides: Memory Hierarchy); the
# budget is the full core's — anything above is an outright compile fault,
# and real kernels co-resident with the pipeline should stay well under.
VMEM_BUDGET_BYTES = 16 * 1024 * 1024
PAD_WASTE_WARN = 0.5


def _findings_from(rep: dict, subject: str, where: str) -> list[Finding]:
    out: list[Finding] = []
    kern = rep["kernel"]
    loc = f"{kern}:{where}"
    for issue in rep["issues"]:
        out.append(Finding(
            check="pallas", code="PAL003", severity=Severity.ERROR,
            subject=subject, location=loc,
            message=f"kernel contract violation: {issue}"))
    for issue in rep.get("soft_issues", ()):
        out.append(Finding(
            check="pallas", code="PAL003", severity=Severity.WARNING,
            subject=subject, location=loc,
            message=f"kernel tiling concern: {issue}"))
    if rep["vmem_bytes"] > VMEM_BUDGET_BYTES:
        out.append(Finding(
            check="pallas", code="PAL001", severity=Severity.ERROR,
            subject=subject, location=loc,
            message=(f"estimated VMEM working set "
                     f"{rep['vmem_bytes'] / 2**20:.1f} MiB exceeds the "
                     f"{VMEM_BUDGET_BYTES / 2**20:.0f} MiB/core budget "
                     f"(grid {rep['grid']}): shrink the block shape")))
    if rep["pad_waste"] > PAD_WASTE_WARN:
        out.append(Finding(
            check="pallas", code="PAL002", severity=Severity.WARNING,
            subject=subject, location=loc,
            message=(f"padding inflates the kernel's work by "
                     f"{rep['pad_waste']:.0%} (grid {rep['grid']}): "
                     "consider smaller blocks or a padded-free layer "
                     "width")))
    return out


@register("pallas")
def check_pallas(target: AnalysisTarget) -> list[Finding]:
    if not target.gemm_shapes and not target.ssd_shapes:
        return []
    from repro.kernels.mrr_transfer import ops as mrr_ops
    from repro.kernels.osa_matmul import ops as osa_ops
    from repro.kernels.rosa_fused import ops as fused_ops
    from repro.kernels.ssd_scan import ops as ssd_ops

    findings: list[Finding] = []
    for name, m, k, n in target.gemm_shapes:
        where = f"{name} {m}x{k}x{n}"
        osa_rep = osa_ops.preflight(m, k, n)
        findings += _findings_from(osa_rep, target.name, where)
        # the WS path realizes the (k, n) weight sheet through mrr_transfer
        findings += _findings_from(
            mrr_ops.preflight(k * n), target.name, where)
        # the fused megakernel covers the same GEMM in one launch; its
        # geometry (grid, padding) is identical to osa_matmul's by
        # construction, so an identical-geometry PAL002 would only restate
        # the warning already filed against osa_matmul under a second
        # fingerprint — suppress the duplicate, keep VMEM/contract findings
        fused_rep = fused_ops.preflight(m, k, n)
        fused_findings = _findings_from(fused_rep, target.name, where)
        if (fused_rep["grid"] == osa_rep["grid"]
                and fused_rep["pad_waste"] == osa_rep["pad_waste"]):
            fused_findings = [f for f in fused_findings
                              if f.code != "PAL002"]
        findings += fused_findings
    for name, bsz, l, h, p, s_dim in target.ssd_shapes:
        findings += _findings_from(
            ssd_ops.preflight(bsz, l, h, p, s_dim), target.name,
            f"{name} B{bsz}xL{l}xH{h}xP{p}xS{s_dim}")
    return findings
