"""Roofline reporter — reads launch/dryrun.py JSON records and derives the
three roofline terms per (arch x shape) cell (deliverable g).

    compute    = HLO_FLOPs_per_dev / peak_FLOP/s      (197 TF/s bf16, v5e)
    memory     = HLO_bytes_per_dev / HBM_bw           (819 GB/s)
    collective = wire_bytes_per_dev / link_bw         (50 GB/s/link ICI)

plus MODEL_FLOPS (6*N*D train / 2*N*D inference, N = active params) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs, which catches remat and
dispatch-padding waste.  All inputs are per-device numbers parsed from the
compiled per-device SPMD module (launch/hlo_analysis.py).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
       [--mesh single] [--fmt md|csv]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

SHAPES = {"train_4k": (4096, 256), "prefill_32k": (32768, 32),
          "decode_32k": (32768, 128), "long_500k": (524288, 1)}


def active_params(arch: str, n_params: int) -> int:
    """Active params per token (MoE: only top-k + shared experts count)."""
    from repro.configs import get_config
    cfg = get_config(arch)
    if cfg.moe is None:
        return n_params
    m = cfg.moe
    n_moe_layers = cfg.n_layers - (1 if cfg.first_dense_ff else 0)
    per_expert = 3 * m.d_model * m.d_ff
    inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
    return n_params - inactive


def model_flops(arch: str, shape: str, n_params: int) -> float:
    seq, batch = SHAPES[shape]
    n_act = active_params(arch, n_params)
    if shape.startswith("train"):
        return 6.0 * n_act * seq * batch
    if shape.startswith("prefill"):
        return 2.0 * n_act * seq * batch
    return 2.0 * n_act * batch          # decode: one token per sequence


def load(dir_: str, mesh: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dir_, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def derive(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    h = rec["hlo"]
    n_dev = rec["n_devices"]
    t_c = h["flops"] / PEAK_FLOPS
    t_m = h["bytes"] / HBM_BW
    t_x = h["coll_wire_total"] / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"], rec["n_params"])
    useful = mf / n_dev / max(h["flops"], 1)
    step_time = max(terms.values())          # no-overlap upper bound
    mfu = mf / n_dev / max(step_time, 1e-30) / PEAK_FLOPS
    return dict(arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
                compute_s=t_c, memory_s=t_m, collective_s=t_x,
                dominant=dom, model_flops=mf, useful_ratio=useful,
                roofline_frac=min(mfu, 1.0),
                mem_gb=(rec.get("memory", {}).get("argument_size_in_bytes")
                        or 0) / 2**30)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--fmt", default="md", choices=["md", "csv"])
    args = ap.parse_args()

    rows = [d for r in load(args.dir, args.mesh) if (d := derive(r))]
    rows.sort(key=lambda d: (d["arch"], d["shape"]))
    if args.fmt == "csv":
        print("arch,shape,compute_s,memory_s,collective_s,dominant,"
              "useful_ratio,roofline_frac,mem_gb")
        for d in rows:
            print(f"{d['arch']},{d['shape']},{d['compute_s']:.4g},"
                  f"{d['memory_s']:.4g},{d['collective_s']:.4g},"
                  f"{d['dominant']},{d['useful_ratio']:.3f},"
                  f"{d['roofline_frac']:.3f},{d['mem_gb']:.2f}")
        return
    print("| arch | shape | compute [s] | memory [s] | collective [s] | "
          "dominant | useful | roofline | mem/dev GB |")
    print("|---|---|---|---|---|---|---|---|---|")
    for d in rows:
        print(f"| {d['arch']} | {d['shape']} | {d['compute_s']:.3e} | "
              f"{d['memory_s']:.3e} | {d['collective_s']:.3e} | "
              f"{d['dominant']} | {d['useful_ratio']:.2f} | "
              f"{d['roofline_frac']:.2f} | {d['mem_gb']:.2f} |")


if __name__ == "__main__":
    main()
