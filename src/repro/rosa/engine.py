"""`Engine` — the single entry point onto the optical path.

The Engine owns the three things every consumer used to re-thread by hand:

  * an `ExecutionPlan` (per-layer RosaConfig resolution, hybrid IS/WS
    mapping included),
  * a base PRNG key plus deterministic per-layer / per-step folding, so
    callers stop plumbing `key=None` through every signature,
  * an optional `EnergyLedger` that records each routed matmul's GEMM shape
    at trace time for trace-based EDP accounting.

Backend selection (dense einsum / pure-jnp OSA ref / Pallas kernel) lives
on each layer's `RosaConfig.backend` and resolves through the registry in
`rosa.backends` — there is no boolean kernel toggle.

Usage:

    key = jax.random.split(caller_key)[0]        # thread, never re-seed:
    engine = Engine.from_hybrid_plan(RosaConfig(noise=mrr.PAPER_NOISE),
                                     {"conv3": Mapping.IS}, key=key)
    y = engine.matmul(x, w, name="conv3")        # folded key, plan config

A constant-baked key (`key=jax.random.PRNGKey(0)` at a call site) makes
every run realize the same device noise — `repro.analysis`'s PRNG check
flags exactly that pattern (PRNG002/PRNG003).
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import warnings
import zlib
from typing import Iterable, Mapping as TMapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mrr
from repro.core.constants import Mapping
from repro.obs import trace as obs
from repro.rosa.backends import (DEFAULT, RosaConfig, condition_weight,
                                 rosa_matmul)
from repro.rosa.ledger import EnergyLedger
from repro.rosa.plan import ExecutionPlan


# Context-LOCAL ambient engine: a ContextVar, not a module-global stack, so
# concurrent serving threads (and asyncio tasks) each see only the engine
# they installed — installing an engine in one request handler can never
# leak into another thread's trace.
_ENGINE_VAR: contextvars.ContextVar["Engine | None"] = \
    contextvars.ContextVar("rosa_ambient_engine", default=None)


def ambient_engine() -> "Engine | None":
    """The innermost engine installed by `engine_context`, or None.

    Model code that routes matmuls optically but takes no engine parameter
    (e.g. a scanned transformer stack with `rosa_mlp=True`) resolves its
    engine here at TRACE time — so a serving loop can pin one fabricated
    chip (`Engine.with_variation`), a hybrid mapping plan and an
    `EnergyLedger` without threading the engine through every model
    signature.  Keep the context active around the `jax.jit` call: it is
    consulted while tracing, not at run time.  Prefer `rosa.compile` — a
    `Program` installs its engine around its own traces, so callers never
    manage this context by hand.
    """
    return _ENGINE_VAR.get()


@contextlib.contextmanager
def engine_context(engine: "Engine | None"):
    """Install `engine` as the ambient optical engine for model code.

    Context-local (thread- and task-safe): nested installs restore the
    previous engine on exit, and other threads are unaffected.
    """
    token = _ENGINE_VAR.set(engine)
    try:
        yield engine
    finally:
        _ENGINE_VAR.reset(token)


def current_engine() -> "Engine | None":
    """Deprecated alias of `ambient_engine` (pre-Program API)."""
    warnings.warn(
        "rosa.current_engine is deprecated; use rosa.ambient_engine(), or "
        "better, rosa.compile(...) which threads the engine for you",
        DeprecationWarning, stacklevel=2)
    return ambient_engine()


def use_engine(engine: "Engine"):
    """Deprecated alias of `engine_context` (pre-Program API)."""
    warnings.warn(
        "rosa.use_engine is deprecated; use rosa.engine_context(engine), or "
        "better, rosa.compile(...) which installs the engine around its own "
        "traces", DeprecationWarning, stacklevel=2)
    return engine_context(engine)


def layer_key(base: jax.Array, name: str, step: int | jax.Array = 0
              ) -> jax.Array:
    """Deterministic per-layer/per-step key: fold the layer name's CRC and
    the step counter into the base key.  Same (base, name, step) -> same
    noise draw, independent draws across layers and steps.
    """
    k = jax.random.fold_in(base, zlib.crc32(name.encode("utf-8")) & 0x7FFFFFFF)
    return jax.random.fold_in(k, step)


@dataclasses.dataclass(frozen=True)
class Engine:
    """Routes every named matmul through the resolved execution plan.

    `variation` pins one sampled chip (`{layer: mrr.StaticVariation}`,
    drawn by `repro.robust.variation`) so every forward — including a
    serving decode loop — sees the SAME fabricated device deterministically;
    `gates` carries traced per-layer scalars in [0, 1] blending the analog
    path against the exact digital one (the vectorized perturb-one-layer
    selector of `repro.robust.sensitivity`); `mapping_gates` carries traced
    per-layer WS/IS selectors ({0=WS, 1=IS}) so a whole hybrid plan becomes
    a float vector — a vmap axis for the MC-verified plan search.
    """

    plan: ExecutionPlan = ExecutionPlan()
    key: jax.Array | None = None
    ledger: EnergyLedger | None = None
    variation: TMapping[str, mrr.StaticVariation] | None = None
    gates: TMapping[str, jax.Array] | None = None
    mapping_gates: TMapping[str, jax.Array] | None = None

    # -- constructors -------------------------------------------------------
    @classmethod
    def dense(cls) -> "Engine":
        """All layers exact dense einsum (no optical path)."""
        return cls(ExecutionPlan())

    @classmethod
    def from_config(cls, cfg: RosaConfig = DEFAULT,
                    layers: Iterable[str] | None = None,
                    key: jax.Array | None = None,
                    ledger: EnergyLedger | None = None) -> "Engine":
        """Every layer runs the same RosaConfig."""
        return cls(ExecutionPlan.build(cfg, None, layers), key, ledger)

    @classmethod
    def from_layer_cfgs(cls, cfgs: TMapping[str, RosaConfig | None],
                        layers: Iterable[str] | None = None,
                        key: jax.Array | None = None,
                        ledger: EnergyLedger | None = None) -> "Engine":
        """Explicit per-layer configs; unnamed layers are dense."""
        return cls(ExecutionPlan.build(None, dict(cfgs), layers), key, ledger)

    @classmethod
    def from_hybrid_plan(cls, cfg: RosaConfig,
                         plan: TMapping[str, Mapping] | None,
                         layers: Iterable[str] | None = None,
                         key: jax.Array | None = None,
                         ledger: EnergyLedger | None = None) -> "Engine":
        """`cfg` everywhere, with the mapping field overridden per layer by
        a `{layer: Mapping}` hybrid plan (core.mapping.hybrid_plan).
        """
        return cls(ExecutionPlan.from_mapping_plan(cfg, plan or {}, layers),
                   key, ledger)

    # -- derivations --------------------------------------------------------
    def with_key(self, key: jax.Array | None) -> "Engine":
        """Copy of the engine with the per-shot PRNG key replaced."""
        return dataclasses.replace(self, key=key)

    def with_ledger(self, ledger: EnergyLedger | None) -> "Engine":
        """Copy of the engine with the energy ledger replaced."""
        return dataclasses.replace(self, ledger=ledger)

    def with_plan(self, plan: ExecutionPlan) -> "Engine":
        """Copy of the engine with the execution plan replaced."""
        return dataclasses.replace(self, plan=plan)

    def with_variation(self, variation: TMapping[str, mrr.StaticVariation]
                       | None) -> "Engine":
        """Pin one sampled chip: every subsequent matmul of layer `name`
        applies `variation[name]` (layers absent from the dict run
        variation-free).  Pass None to unpin.
        """
        return dataclasses.replace(
            self, variation=dict(variation) if variation is not None
            else None)

    def with_gates(self, gates: TMapping[str, jax.Array] | None) -> "Engine":
        """Per-layer analog/digital blend gates (traced scalars in [0,1])."""
        return dataclasses.replace(
            self, gates=dict(gates) if gates is not None else None)

    def with_mapping_gates(self, mapping_gates: TMapping[str, jax.Array]
                           | None) -> "Engine":
        """Per-layer WS/IS selectors ({0=WS, 1=IS}, traced): superpose the
        two mapping orientations so plan candidates can be vmapped.
        """
        return dataclasses.replace(
            self, mapping_gates=dict(mapping_gates)
            if mapping_gates is not None else None)

    # -- resolution ---------------------------------------------------------
    @property
    def is_dense(self) -> bool:
        """Whether every layer resolves to the dense digital path."""
        return self.plan.is_dense

    def config(self, name: str) -> RosaConfig | None:
        """Resolved per-layer config (None = dense fallback)."""
        return self.plan.resolve(name)

    def key_for(self, name: str, step: int | jax.Array = 0
                ) -> jax.Array | None:
        """Per-layer, per-step PRNG key, or None when keyless."""
        return None if self.key is None else layer_key(self.key, name, step)

    def variation_for(self, name: str) -> mrr.StaticVariation | None:
        """The pinned chip's variation for one layer, if any."""
        return None if self.variation is None else self.variation.get(name)

    def gate_for(self, name: str) -> jax.Array | None:
        """The analog-blend gate for one layer, if any."""
        return None if self.gates is None else self.gates.get(name)

    def mapping_gate_for(self, name: str) -> jax.Array | None:
        """The WS/IS mapping gate for one layer, if any."""
        return None if self.mapping_gates is None \
            else self.mapping_gates.get(name)

    # -- the routed matmul --------------------------------------------------
    def matmul(self, x: jax.Array, w: jax.Array, *, name: str = "",
               step: int | jax.Array = 0,
               key: jax.Array | None = None) -> jax.Array:
        """Compute y = x @ w through this layer's resolved config.

        x: (..., K); w: (K, N).  An explicit `key` overrides the engine's
        folded per-layer key.  Dense layers (resolved config None) contract
        exactly in the caller's dtype.
        """
        cfg = self.plan.resolve(name)
        if obs.enabled():
            # fires at JAX trace time only — one instant per traced matmul,
            # none per executed step — so the compile timeline shows every
            # shape the engine routes (and which fall through to dense)
            obs.instant("rosa.matmul", "compile", layer=name or "unnamed",
                        m=int(np.prod(x.shape[:-1], dtype=np.int64)),
                        k=int(x.shape[-1]), n=int(w.shape[-1]),
                        dense=cfg is None)
        if cfg is None:
            return jnp.einsum("...k,kn->...n", x, w)
        if self.ledger is not None:
            # unnamed matmuls get a shape-stable synthetic name so re-traces
            # and MC loops dedupe to one event instead of inflating EDP;
            # the flip side is that distinct unnamed layers of identical
            # (m, k, n) collapse into one event — pass `name=` for per-layer
            # accounting
            m = int(np.prod(x.shape[:-1], dtype=np.int64))
            k, n = int(x.shape[-1]), int(w.shape[-1])
            self.ledger.record(name or f"unnamed_{m}x{k}x{n}",
                               m=m, k=k, n=n, cfg=cfg)
        if key is None:
            key = self.key_for(name, step)
        return rosa_matmul(x.astype(jnp.float32), w.astype(jnp.float32),
                           cfg, key, self.variation_for(name),
                           self.gate_for(name), self.mapping_gate_for(name))

    def effective_weight(self, w: jax.Array, *, name: str = "",
                         step: int | jax.Array = 0,
                         key: jax.Array | None = None) -> jax.Array:
        """Noise-place a weight tensor for contractions the engine does not
        route itself (per-channel depthwise convs): same analog realization,
        variation pinning and gate blending as `matmul`'s WS side; identity
        for dense or fully ideal layers.
        """
        cfg = self.plan.resolve(name)
        if key is None:
            key = self.key_for(name, step)
        return condition_weight(w, cfg, key, self.variation_for(name),
                                self.gate_for(name))
