"""LR schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(base_lr: float, warmup_steps: int):
    def f(step):
        frac = jnp.minimum(step / max(warmup_steps, 1), 1.0)
        return base_lr * frac
    return f


def cosine_schedule(base_lr: float, warmup_steps: int, total_steps: int,
                    min_frac: float = 0.1):
    def f(step):
        warm = jnp.minimum(step / max(warmup_steps, 1), 1.0)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * warm * cos
    return f
