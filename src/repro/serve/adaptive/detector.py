"""Drift detection: alpha-beta temperature tracker + CUSUM statistic.

Two independent signals feed the controller:

  * an alpha-beta (g-h) filter over the (noisy) temperature-sensor
    readings — a level + rate estimate of the thermal offset, so the
    one-tick-ahead `predict()` a re-trim programs into
    `voltage_of_weight(dt_trim=...)` leads a moving drift instead of
    lagging it (a plain EWMA trails a 2pi*amp/period ramp by ~1/alpha
    ticks, which is most of the residual budget at probe sensitivity);
  * a one-sided CUSUM over the probe-agreement DROP (reference minus
    measured, minus a slack `k`): transient single-probe noise is
    absorbed by the slack, while a sustained drop integrates past the
    threshold `h` and fires.

Hysteresis is explicit: once fired, the detector stays in the degraded
regime until `rearm` consecutive probes sit back inside the slack band —
so the controller never flaps around the threshold.  All state is plain
Python floats on the host; nothing here touches a trace.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    """Detection thresholds (agreement in [0, 1] units, temps in K)."""

    ewma_alpha: float = 0.5    # level gain (1 = trust last reading)
    rate_beta: float = 0.3     # rate gain of the alpha-beta tracker
    cusum_k: float = 0.02      # slack: drops below this never accumulate
    cusum_h: float = 0.04      # decision threshold on the CUSUM sum
    rearm: int = 2             # consecutive in-band probes to re-arm


class DriftDetector:
    """Host-side detector state; `observe_temp` once per tick,
    `update` once per probe window."""

    def __init__(self, cfg: DetectorConfig, ref_agreement: float):
        self.cfg = cfg
        self.ref = float(ref_agreement)
        self.temp_estimate_k = 0.0   # filtered level [K]
        self.temp_rate_k = 0.0       # filtered rate [K per observation]
        self.cusum = 0.0
        self.fired = False
        self._seeded = False
        self._ok_streak = 0

    def observe_temp(self, sensed_k: float) -> float:
        """Predict-correct one sensor reading; returns the level."""
        a, b = self.cfg.ewma_alpha, self.cfg.rate_beta
        if not self._seeded:
            self.temp_estimate_k = float(sensed_k)
            self._seeded = True
        else:
            pred = self.temp_estimate_k + self.temp_rate_k
            r = float(sensed_k) - pred
            self.temp_estimate_k = pred + a * r
            self.temp_rate_k += b * r
        return self.temp_estimate_k

    def predict(self) -> float:
        """One-observation-ahead temperature [K] — what a trim applied
        between ticks should program for the NEXT tick's plant."""
        return self.temp_estimate_k + self.temp_rate_k

    def update(self, agreement: float) -> bool:
        """Fold one probe score; True while the degraded regime holds."""
        drop = self.ref - float(agreement)
        self.cusum = max(0.0, self.cusum + drop - self.cfg.cusum_k)
        if self.cusum > self.cfg.cusum_h:
            self.fired = True
            self._ok_streak = 0
        elif self.fired:
            if drop <= self.cfg.cusum_k:
                self._ok_streak += 1
                if self._ok_streak >= self.cfg.rearm:
                    self.reset()
            else:
                self._ok_streak = 0
        return self.fired

    def reset(self) -> None:
        """Re-arm after a successful corrective action (or hysteresis)."""
        self.cusum = 0.0
        self.fired = False
        self._ok_streak = 0
