"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps).

The property sections fuzz the osa_matmul / mrr_transfer kernels against
their ref.py oracles over randomized shapes, dtypes and edge tiles
(hypothesis when installed, fixed-sample parametrization otherwise — the
same guard pattern as tests/test_mrr.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                      # degrade gracefully: property tests fall back to
    import hypothesis as hp            # fixed-sample parametrization when
    import hypothesis.strategies as st  # hypothesis is not installed
except ModuleNotFoundError:
    hp = st = None

from repro.core import mrr, osa, quant
from repro.core.constants import ComputeMode, Mapping
from repro.kernels.mrr_transfer import mrr_transfer as mt_kernel
from repro.kernels.mrr_transfer import ops as mt_ops
from repro.kernels.mrr_transfer import ref as mt_ref
from repro.kernels.osa_matmul import ops as osa_ops
from repro.kernels.osa_matmul.ref import osa_matmul_ref
from repro.kernels.rosa_fused import ops as fused_ops
from repro.kernels.rosa_fused import ref as fused_ref
from repro.kernels.rosa_fused import rosa_fused as fused_kernel
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan import ref as ssd_ref


# ---------------------------------------------------------------------------
# osa_matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (32, 48, 24), (17, 33, 5),
                                   (128, 128, 128)])
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.analog_guard
def test_osa_kernel_matches_ref(m, k, n, bits, key):
    k1, k2 = jax.random.split(key)
    cfg = quant.QuantConfig(bits=bits)
    q = jnp.round(jax.random.uniform(k1, (m, k), minval=-cfg.qmax,
                                     maxval=cfg.qmax))
    w = jax.random.normal(k2, (k, n))
    y = osa_ops.osa_matmul_int(q, w, quant.plane_weights(cfg),
                               n_planes=cfg.n_planes, bm=8, bn=8, bk=8)
    y_ref = osa_matmul_ref(q, w, quant_bits=bits)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("fused", [True, False])
def test_osa_kernel_fused_vs_per_plane(fused, key):
    k1, k2 = jax.random.split(key)
    q = jnp.round(jax.random.uniform(k1, (16, 24), minval=-127, maxval=127))
    w = jax.random.normal(k2, (24, 8))
    y = osa_ops.osa_matmul_int(q, w, quant.plane_weights(), n_planes=7,
                               fused=fused, bm=8, bn=8, bk=8)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(osa_matmul_ref(q, w)),
                               rtol=1e-4, atol=1e-3)


def test_osa_kernel_nonideal_gains(key):
    """Calibrated (non power-of-two) slot gains flow through the kernel."""
    k1, k2, k3 = jax.random.split(key, 3)
    q = jnp.round(jax.random.uniform(k1, (8, 16), minval=-127, maxval=127))
    w = jax.random.normal(k2, (16, 4))
    gains = quant.plane_weights() * (1 + 0.01 * jax.random.normal(k3, (7,)))
    y = osa_ops.osa_matmul_int(q, w, gains, n_planes=7, bm=8, bn=8, bk=8)
    y_ref = osa_matmul_ref(q, w, gains=gains)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.analog_guard
def test_osa_float_entrypoint(key):
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (9, 21))
    w = jax.random.normal(k2, (21, 6))
    y = osa_ops.osa_matmul(x, w, bm=8, bn=8, bk=8)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(quant.fake_quant(x) @ w),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# osa_matmul / mrr_transfer property fuzzing vs ref.py
# ---------------------------------------------------------------------------
def _check_osa_parity(m: int, k: int, n: int, bits: int, seed: int,
                      wdtype=jnp.float32) -> None:
    """Kernel == oracle for arbitrary (possibly non-tile-aligned) shapes."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    cfg = quant.QuantConfig(bits=bits)
    q = jnp.round(jax.random.uniform(k1, (m, k), minval=-cfg.qmax,
                                     maxval=cfg.qmax))
    w = jax.random.normal(k2, (k, n)).astype(wdtype)
    y = osa_ops.osa_matmul_int(q, w, quant.plane_weights(cfg),
                               n_planes=cfg.n_planes, bm=8, bn=8, bk=8)
    y_ref = osa_matmul_ref(q, w, quant_bits=bits)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=2e-3)


def _check_mrr_ideal_parity(rows: int, cols: int, seed: int,
                            lo: float, hi: float) -> None:
    """sigma=0: kernel == oracle exactly (up to interpolation tolerance)
    for arbitrary shapes, including non-lane-aligned ones."""
    w = jax.random.uniform(jax.random.PRNGKey(seed), (rows, cols),
                           minval=lo, maxval=hi)
    out_k = mt_ops.mrr_transfer(w, jax.random.PRNGKey(seed + 1),
                                sigma_dac=0.0, sigma_th=0.0)
    z = jnp.zeros_like(w)
    out_r = mt_ref.mrr_transfer_ref(w, z, z, sigma_dac=0.0, sigma_th=0.0)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=5e-4)


def _check_mrr_noisy_parity(n: int, seed: int, sigma_dac: float,
                            sigma_th: float) -> None:
    """Noisy parity: replicate ops.mrr_transfer's internal noise layout
    (flatten -> pad to (rows, 128) -> split key -> two normals) so the
    kernel and the oracle consume IDENTICAL draws."""
    key = jax.random.PRNGKey(seed)
    w = jax.random.uniform(jax.random.fold_in(key, 1), (n,),
                           minval=-1.0, maxval=1.0)
    out_k = mt_ops.mrr_transfer(w, key, sigma_dac=sigma_dac,
                                sigma_th=sigma_th)
    rows = -(-n // 128)
    rows_pad = -(-rows // 8) * 8
    flat = jnp.pad(w, (0, rows_pad * 128 - n)).reshape(rows_pad, 128)
    k1, k2 = jax.random.split(key)
    e_dac = jax.random.normal(k1, flat.shape, flat.dtype)
    e_th = jax.random.normal(k2, flat.shape, flat.dtype)
    out_r = mt_ref.mrr_transfer_ref(flat, e_dac, e_th,
                                    sigma_dac=sigma_dac, sigma_th=sigma_th)
    np.testing.assert_allclose(np.asarray(out_k),
                               np.asarray(out_r.reshape(-1)[:n]),
                               atol=5e-4)


if hp is not None:
    @hp.given(st.integers(1, 40), st.integers(1, 64), st.integers(1, 24),
              st.sampled_from([4, 6, 8]), st.integers(0, 2 ** 16))
    @hp.settings(max_examples=12, deadline=None)
    def test_osa_parity_property(m, k, n, bits, seed):
        _check_osa_parity(m, k, n, bits, seed)

    @hp.given(st.integers(1, 40), st.integers(1, 64),
              st.integers(0, 2 ** 16))
    @hp.settings(max_examples=8, deadline=None)
    def test_osa_parity_bf16_property(m, k, seed):
        _check_osa_parity(m, k, 8, 8, seed, wdtype=jnp.bfloat16)

    @hp.given(st.integers(1, 40), st.integers(1, 40),
              st.integers(0, 2 ** 16),
              st.floats(-1.0, 0.0), st.floats(0.0, 1.0))
    @hp.settings(max_examples=10, deadline=None)
    def test_mrr_ideal_parity_property(rows, cols, seed, lo, hi):
        _check_mrr_ideal_parity(rows, cols, seed, lo, max(hi, lo + 1e-3))

    @hp.given(st.integers(1, 700), st.integers(0, 2 ** 16),
              st.floats(0.0, 0.05), st.floats(0.0, 0.1))
    @hp.settings(max_examples=10, deadline=None)
    def test_mrr_noisy_parity_property(n, seed, sigma_dac, sigma_th):
        _check_mrr_noisy_parity(n, seed, sigma_dac, sigma_th)
else:
    @pytest.mark.parametrize("m,k,n,bits,seed", [
        (1, 1, 1, 8, 0), (7, 9, 3, 4, 1), (8, 8, 8, 6, 2),
        (9, 17, 8, 8, 3), (33, 64, 24, 8, 4), (40, 5, 1, 4, 5),
        (16, 48, 9, 6, 6), (25, 31, 17, 8, 7)])
    def test_osa_parity_property(m, k, n, bits, seed):
        _check_osa_parity(m, k, n, bits, seed)

    @pytest.mark.parametrize("m,k,seed", [(5, 12, 0), (17, 33, 1),
                                          (40, 64, 2)])
    def test_osa_parity_bf16_property(m, k, seed):
        _check_osa_parity(m, k, 8, 8, seed, wdtype=jnp.bfloat16)

    @pytest.mark.parametrize("rows,cols,seed,lo,hi", [
        (1, 1, 0, -1.0, 1.0), (3, 7, 1, -0.5, 0.5), (16, 8, 2, -1.0, 0.0),
        (33, 7, 3, 0.0, 1.0), (40, 40, 4, -0.9, 0.9)])
    def test_mrr_ideal_parity_property(rows, cols, seed, lo, hi):
        _check_mrr_ideal_parity(rows, cols, seed, lo, hi)

    @pytest.mark.parametrize("n,seed,sd,sth", [
        (1, 0, 0.02, 0.04), (127, 1, 0.0, 0.1), (128, 2, 0.05, 0.0),
        (129, 3, 0.02, 0.04), (700, 4, 0.01, 0.02)])
    def test_mrr_noisy_parity_property(n, seed, sd, sth):
        _check_mrr_noisy_parity(n, seed, sd, sth)


# ---------------------------------------------------------------------------
# rosa_fused megakernel vs the composed-chain oracle
# ---------------------------------------------------------------------------
# A pinned non-ideal environment exercising every fused stage at once:
# per-shot DAC/thermal noise, static chip variation (a per-lane dv field),
# and OSA chain non-idealities.  Individual knobs zero out per-case below.
_F_NOISE = mrr.PAPER_NOISE
_F_OSA = osa.OSAConfig(splitter_imbalance=0.01, odl_loss_db_per_stage=0.05)


def _f_var(k_dim: int, seed: int) -> mrr.StaticVariation:
    dv = 0.01 * jax.random.normal(jax.random.PRNGKey(seed ^ 0xA5), (k_dim,))
    return mrr.StaticVariation(dv=dv, ddt=jnp.float32(0.05),
                               dlam=jnp.float32(1e-4))


def assert_quantized_parity(y, y_ref, *, qmax: int = 127,
                            tight: float = 2e-4) -> None:
    """Parity assertion for two implementations of the same quantized
    pipeline computed in different float op orders.

    The fused kernel re-derives the realization chain with noise/variation
    folded into additive offsets, so a conditioned activation can differ
    from the composed chain's by ~1 ulp; when such a value lands within
    float noise of a requantization rounding boundary its 8-bit code flips
    by ONE.  A flip moves every output of that activation row by at most
    one requant LSB (~1/qmax of the output's full scale).  So: the bulk
    must match at float-accumulation tightness, deviations may never
    exceed the one-LSB bound, and flipped rows must stay rare."""
    y = np.asarray(y, np.float64).reshape(-1, y.shape[-1])
    r = np.asarray(y_ref, np.float64).reshape(y.shape)
    scale = max(float(np.max(np.abs(r))), 1.0)
    d = np.abs(y - r) / scale
    assert d.max() <= 2.0 / qmax, \
        f"deviation {d.max():.2e} exceeds the one-LSB flip bound"
    bad_rows = int((d.max(axis=-1) > tight).sum())
    allowed = max(2, -(-y.shape[0] // 4))
    assert bad_rows <= allowed, \
        (f"{bad_rows} rows (of {y.shape[0]}) beyond the tight tolerance — "
         "more than requant boundary flips can explain")


def _check_fused_parity(m: int, k: int, n: int, seed: int, *,
                        mapping=Mapping.WS, mode=ComputeMode.MIXED,
                        apv: bool = False, noisy: bool = True,
                        with_var: bool = True, gate=None, mgate=None,
                        pam_bits: int = 1, osa_cfg=_F_OSA) -> None:
    """Fused kernel == composed quantize->realize->OSA->dequant oracle.

    Same key in, bit-identical noise draws by contract — tolerances are
    the flip-aware quantized-parity discipline (see
    assert_quantized_parity)."""
    kx, kw, kn = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n))
    noise = _F_NOISE if noisy else mrr.IDEAL
    var = _f_var(k, seed) if with_var else None
    kwargs = dict(mapping=mapping, mode=mode, noise=noise,
                  act_per_vector=apv, pam_bits=pam_bits, osa_cfg=osa_cfg)
    y = fused_ops.rosa_fused_matmul(x, w, kn, var, gate, mgate,
                                    bm=8, bn=128, bk=128, **kwargs)
    y_ref = fused_ref.rosa_fused_ref(x, w, kn, var, gate, mgate, **kwargs)
    assert_quantized_parity(y, y_ref)


_FUSED_CASES = [
    # (m, k, n, seed, kwargs) — mappings x per-vector x gates x non-ideal
    (8, 16, 8, 0, {}),
    (12, 70, 33, 1, {"mapping": Mapping.IS}),
    (12, 70, 33, 2, {"mapping": Mapping.IS, "apv": True}),
    (9, 130, 40, 3, {"apv": True}),                 # K pad lanes masked
    (17, 128, 5, 4, {"noisy": False}),              # variation-only realize
    (8, 32, 8, 5, {"noisy": False, "with_var": False}),   # ideal shortcut
    (9, 33, 8, 6, {"gate": 0.3}),
    (16, 48, 24, 7, {"mgate": 0.5, "apv": True}),   # mapping superposition
    (8, 40, 16, 8, {"mode": ComputeMode.ANALOG}),
    (8, 40, 16, 9, {"mode": ComputeMode.ANALOG, "gate": 0.7}),
    (8, 24, 8, 10, {"pam_bits": 2}),                # PAM-4 digits
]


@pytest.mark.parametrize("m,k,n,seed,kwargs", _FUSED_CASES)
def test_fused_matches_composed_chain(m, k, n, seed, kwargs):
    _check_fused_parity(m, k, n, seed, **kwargs)


if hp is not None:
    @hp.given(st.integers(1, 24), st.integers(1, 150), st.integers(1, 16),
              st.sampled_from([Mapping.WS, Mapping.IS]), st.booleans(),
              st.booleans(), st.integers(0, 2 ** 16))
    @hp.settings(max_examples=8, deadline=None)
    def test_fused_parity_property(m, k, n, mapping, apv, with_var, seed):
        _check_fused_parity(m, k, n, seed, mapping=mapping, apv=apv,
                            with_var=with_var)

    @hp.given(st.integers(1, 16), st.integers(1, 140), st.integers(1, 12),
              st.integers(0, 2 ** 16))
    @hp.settings(max_examples=4, deadline=None)
    def test_fused_analog_parity_property(m, k, n, seed):
        _check_fused_parity(m, k, n, seed, mode=ComputeMode.ANALOG)
else:
    @pytest.mark.parametrize("m,k,n,mapping,apv,with_var,seed", [
        (1, 1, 1, Mapping.WS, False, True, 0),
        (7, 129, 3, Mapping.IS, True, True, 1),
        (24, 64, 16, Mapping.WS, True, False, 2),
        (16, 150, 9, Mapping.IS, False, True, 3)])
    def test_fused_parity_property(m, k, n, mapping, apv, with_var, seed):
        _check_fused_parity(m, k, n, seed, mapping=mapping, apv=apv,
                            with_var=with_var)

    @pytest.mark.parametrize("m,k,n,seed", [(1, 1, 1, 0), (9, 140, 7, 1)])
    def test_fused_analog_parity_property(m, k, n, seed):
        _check_fused_parity(m, k, n, seed, mode=ComputeMode.ANALOG)


def test_fused_rejects_digital_mode(key):
    x = jax.random.normal(key, (8, 16))
    with pytest.raises(ValueError, match="DIGITAL"):
        fused_ops.rosa_fused_matmul(x, x.T @ x, mode=ComputeMode.DIGITAL)


# ---------------------------------------------------------------------------
# preflight defaults == launch defaults (all four kernels)
# ---------------------------------------------------------------------------
def _defaults(fn) -> dict:
    import inspect
    return {name: p.default for name, p in
            inspect.signature(fn).parameters.items()
            if p.default is not inspect.Parameter.empty}


@pytest.mark.parametrize("preflight,launchers,shared", [
    (osa_ops.preflight, [osa_ops.osa_matmul],
     ("bm", "bn", "bk", "quant_bits", "pam_bits")),
    (mt_ops.preflight, [mt_ops.mrr_transfer, mt_kernel.mrr_transfer_pallas],
     ("block_rows",)),
    (ssd_ops.preflight, [ssd_ops.ssd_scan], ("chunk",)),
    (fused_ops.preflight, [fused_ops.rosa_fused_matmul],
     ("bm", "bn", "bk", "quant_bits", "pam_bits")),
], ids=["osa_matmul", "mrr_transfer", "ssd_scan", "rosa_fused"])
def test_preflight_defaults_match_kernel_defaults(preflight, launchers,
                                                  shared):
    """The analysis sweep must price the launch configuration that actually
    runs: every default a preflight shares with its wrapper/kernel is
    pinned equal (the mrr_transfer block_rows=8 vs 256 drift hid wrong
    VMEM/grid numbers behind a green check)."""
    pre = _defaults(preflight)
    for launcher in launchers:
        got = _defaults(launcher)
        for name in shared:
            assert name in pre and name in got, \
                f"{launcher.__name__} lost shared default {name!r}"
            assert pre[name] == got[name], \
                (f"preflight default {name}={pre[name]} disagrees with "
                 f"{launcher.__name__}'s {name}={got[name]}")


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("l,chunk", [(16, 8), (24, 8), (17, 8)])
@pytest.mark.parametrize("h,g,p,s", [(4, 2, 8, 4), (2, 1, 16, 8)])
def test_ssd_kernel_matches_sequential(l, chunk, h, g, p, s, key):
    ks = jax.random.split(key, 4)
    b = 2
    x = jax.random.normal(ks[0], (b, l, h, p))
    loga = -jnp.abs(jax.random.normal(ks[1], (b, l, h))) * 0.2
    bb = jax.random.normal(ks[2], (b, l, g, s))
    cc = jax.random.normal(ks[3], (b, l, g, s))
    y, sf = ssd_ops.ssd_scan(x, loga, bb, cc, chunk=chunk)
    rep = h // g
    for bi in range(b):
        for hi in range(h):
            gi = hi // rep
            y_r, s_r = ssd_ref.ssd_scan_ref(
                x[bi, :, hi], jnp.exp(loga[bi, :, hi]), bb[bi, :, gi],
                cc[bi, :, gi])
            np.testing.assert_allclose(np.asarray(y[bi, :, hi]),
                                       np.asarray(y_r), rtol=2e-3, atol=2e-3)
            np.testing.assert_allclose(np.asarray(sf[bi, hi]),
                                       np.asarray(s_r), rtol=2e-3, atol=2e-3)


def test_ssd_chunked_ref_matches_sequential(key):
    ks = jax.random.split(key, 4)
    l, p, s = 32, 8, 4
    x = jax.random.normal(ks[0], (l, p))
    a = jnp.exp(-jnp.abs(jax.random.normal(ks[1], (l,))) * 0.3)
    bb = jax.random.normal(ks[2], (l, s))
    cc = jax.random.normal(ks[3], (l, s))
    y1, s1 = ssd_ref.ssd_scan_ref(x, a, bb, cc)
    y2, s2 = ssd_ref.ssd_scan_chunked_ref(x, a, bb, cc, chunk=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# mrr_transfer
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(16, 8), (64, 32), (33, 7)])
def test_mrr_transfer_ideal_matches_ref(shape, key):
    w = jax.random.uniform(key, shape, minval=-1, maxval=1)
    out_k = mt_ops.mrr_transfer(w, key, sigma_dac=0.0, sigma_th=0.0)
    z = jnp.zeros_like(w)
    out_r = mt_ref.mrr_transfer_ref(w, z, z, sigma_dac=0.0, sigma_th=0.0)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=5e-4)


def test_mrr_transfer_noise_statistics(key):
    """Kernel noise std matches the behavioural model's Monte-Carlo std."""
    w = jnp.zeros((4096,))
    out = mt_ops.mrr_transfer(w.reshape(64, 64), key)
    std_kernel = float(jnp.std(out))
    std_model = float(mrr.weight_noise_std(jnp.zeros(()), key, 256))
    assert std_kernel == pytest.approx(std_model, rel=0.35)
