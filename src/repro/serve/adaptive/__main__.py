"""CLI: `python -m repro.serve.adaptive [--kind sine|linear|walk] ...`

Runs one drift-serving A/B (uncontrolled monitor vs closed-loop
controller over the same request stream and compiled step) and prints the
scenario summary; `--json` saves a BENCH-schema report, `--trace` a
Chrome trace of the whole run (controller spans included).
"""

from __future__ import annotations

import argparse
import json


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve.adaptive",
        description="closed-loop drift-adaptive serving scenario")
    ap.add_argument("--kind", default="sine",
                    choices=("sine", "linear", "walk"))
    ap.add_argument("--amp-k", type=float, default=1.2,
                    help="peak thermal offset [K]")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.5)
    ap.add_argument("--period-ticks", type=float, default=64.0)
    ap.add_argument("--probe-every", type=int, default=4)
    ap.add_argument("--force-replan-at", type=int, default=None,
                    help="deterministically trigger a plan swap at a tick")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, help="write a BENCH report")
    ap.add_argument("--trace", default=None, help="write a Chrome trace")
    args = ap.parse_args(argv)

    import contextlib

    from repro.obs import trace as obs
    from repro.serve.adaptive.scenario import ScenarioConfig, run_scenario

    cfg = ScenarioConfig(kind=args.kind, amp_k=args.amp_k,
                         n_requests=args.requests, rate=args.rate,
                         period_ticks=args.period_ticks,
                         probe_every=args.probe_every,
                         force_replan_at=args.force_replan_at,
                         seed=args.seed)
    tracer = obs.Tracer() if args.trace else None
    ctx = obs.tracing(tracer) if tracer is not None \
        else contextlib.nullcontext()
    with ctx:
        res, reqs = run_scenario(cfg)
    if tracer is not None:
        tracer.save(args.trace)
        print(f"trace -> {args.trace}")

    s = res.summary()
    s["dropped_requests"] = res.dropped_requests(reqs)
    print(f"drift={cfg.kind} amp={cfg.amp_k}K  "
          f"requests={cfg.n_requests}  probes every {cfg.probe_every} ticks")
    for k, v in s.items():
        print(f"  {k:24s} {v}")
    if args.json:
        import time

        from repro.bench.schema import BenchResult, save_report
        from repro.serve.adaptive.scenario import drift_serve_metrics
        t0 = time.perf_counter()
        _, metrics = drift_serve_metrics(quick=True)
        save_report([BenchResult(name="drift_serve",
                                 wall_s=time.perf_counter() - t0,
                                 metrics=metrics)], args.json)
        print(f"report -> {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
