"""Pure-jnp oracle for the fused ROSA megakernel.

Replicates, from `repro.core` primitives only, exactly what the composed
`rosa.backends._forward` pipeline computes with the "ref" contraction
backend: operand conditioning (digital EO path / noisy analog realization /
gate blend / mapping-gate superposition) followed by the OSA reference
matmul.  The kernel wrapper (ops.py) also reuses `condition_x` to obtain
the requantization full-scale — a global reduction the tiled kernel cannot
see — so the scale the kernel dequantizes by is bit-identical to the one
the composed chain would use.

Key discipline matches `_forward`: with a mapping gate (or in ANALOG mode)
the caller's key splits into (k_w, k_x); static WS sends the whole key to
the weight side, static IS to the activation side.  `realize_weights`
splits each side's key into (DAC, thermal) draws internally, so the
wrapper's pre-drawn offsets consume the same Gaussians bit-for-bit.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import mrr, osa
from repro.core import quant as Q
from repro.core.constants import ComputeMode, Mapping


def analog_operand(t: jax.Array, key: jax.Array | None, *,
                   qcfg: Q.QuantConfig, p: mrr.MRRParams,
                   noise: mrr.NoiseModel, var: mrr.StaticVariation | None,
                   gate: jax.Array | None, clean_per_vector: bool,
                   noisy_per_vector: bool) -> jax.Array:
    """rosa.backends._analog_operand with the per-vector flags explicit."""
    clean = Q.fake_quant(t, qcfg, per_vector=clean_per_vector)
    if noise.is_ideal and var is None and gate is None:
        return clean
    scale = Q.absmax_scale(t, noisy_per_vector)
    q = Q.fake_quant(t / scale, qcfg)
    noisy = mrr.realize_weights(q, key, p, noise, var) * scale
    if gate is None:
        return noisy
    return clean + gate * (noisy - clean)


def condition_x(x: jax.Array, key: jax.Array | None, *,
                x_active: bool, use_mgate: bool,
                mgate: jax.Array | None, gate: jax.Array | None,
                var: mrr.StaticVariation | None, qcfg: Q.QuantConfig,
                p: mrr.MRRParams, noise: mrr.NoiseModel,
                act_per_vector: bool) -> jax.Array:
    """The MIXED-mode activation operand exactly as `_forward` builds it."""
    x_dig = Q.fake_quant(x, qcfg, per_vector=act_per_vector)
    if use_mgate:
        x_is = analog_operand(x, key, qcfg=qcfg, p=p, noise=noise, var=var,
                              gate=gate, clean_per_vector=act_per_vector,
                              noisy_per_vector=True)
        return (1.0 - mgate) * x_dig + mgate * x_is
    if x_active:
        return analog_operand(x, key, qcfg=qcfg, p=p, noise=noise, var=var,
                              gate=gate, clean_per_vector=act_per_vector,
                              noisy_per_vector=True)
    return x_dig


def condition_w(w: jax.Array, key: jax.Array | None, *,
                w_active: bool, use_mgate: bool,
                mgate: jax.Array | None, gate: jax.Array | None,
                var: mrr.StaticVariation | None, qcfg: Q.QuantConfig,
                p: mrr.MRRParams, noise: mrr.NoiseModel) -> jax.Array:
    """The MIXED-mode weight operand exactly as `_forward` builds it."""
    if use_mgate:
        w_ws = analog_operand(w, key, qcfg=qcfg, p=p, noise=noise,
                              var=mrr.expand_lanes(var, w), gate=gate,
                              clean_per_vector=False, noisy_per_vector=False)
        return (1.0 - mgate) * w_ws + mgate * Q.fake_quant(w, qcfg)
    if w_active:
        return analog_operand(w, key, qcfg=qcfg, p=p, noise=noise,
                              var=mrr.expand_lanes(var, w), gate=gate,
                              clean_per_vector=False, noisy_per_vector=False)
    return Q.fake_quant(w, qcfg)


def rosa_fused_ref(x: jax.Array, w: jax.Array, key: jax.Array | None = None,
                   var: mrr.StaticVariation | None = None,
                   gate: jax.Array | None = None,
                   mgate: jax.Array | None = None, *,
                   mapping: Mapping = Mapping.WS,
                   mode: ComputeMode = ComputeMode.MIXED,
                   quant_bits: int = 8, pam_bits: int = 1,
                   act_per_vector: bool = False,
                   noise: mrr.NoiseModel = mrr.IDEAL,
                   osa_cfg: osa.OSAConfig = osa.IDEAL_OSA,
                   p: mrr.MRRParams = mrr.DEFAULT_PARAMS) -> jax.Array:
    """Composed quantize -> realize -> OSA -> dequantize chain, the oracle
    the fused kernel is fuzz-tested against (same split as `_forward` with
    the "ref" backend)."""
    qcfg = Q.QuantConfig(bits=quant_bits)
    use_mgate = mgate is not None and mode is ComputeMode.MIXED
    if mode is ComputeMode.ANALOG:
        k_w, k_x = (jax.random.split(key) if key is not None
                    else (None, None))
        w_eff = analog_operand(w, k_w, qcfg=qcfg, p=p, noise=noise,
                               var=mrr.expand_lanes(var, w), gate=gate,
                               clean_per_vector=False,
                               noisy_per_vector=False)
        x_eff = analog_operand(x, k_x, qcfg=qcfg, p=p, noise=noise, var=var,
                               gate=gate, clean_per_vector=False,
                               noisy_per_vector=False)
        return x_eff @ w_eff
    if mode is not ComputeMode.MIXED:
        raise ValueError(f"unsupported mode for the fused path: {mode}")
    w_active = use_mgate or mapping in (Mapping.WS, Mapping.GEMM)
    x_active = use_mgate or not w_active
    if use_mgate:
        k_w, k_x = (jax.random.split(key) if key is not None
                    else (None, None))
    elif w_active:
        k_w, k_x = key, None
    else:
        k_w, k_x = None, key
    w_eff = condition_w(w, k_w, w_active=w_active, use_mgate=use_mgate,
                        mgate=mgate, gate=gate, var=var, qcfg=qcfg, p=p,
                        noise=noise)
    x_eff = condition_x(x, k_x, x_active=x_active, use_mgate=use_mgate,
                        mgate=mgate, gate=gate, var=var, qcfg=qcfg, p=p,
                        noise=noise, act_per_vector=act_per_vector)
    return osa.osa_matmul_ref(
        x_eff, w_eff, dataclasses.replace(osa_cfg, pam_bits=pam_bits),
        qcfg, per_vector=act_per_vector)
