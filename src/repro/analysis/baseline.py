"""Committed findings baseline: acknowledge, don't silence.

The baseline file maps finding fingerprints to human-readable labels.  CI
fails on findings NOT in the baseline, so a new hazard blocks merge while
the acknowledged backlog doesn't; deleting an entry re-arms its finding.
Fingerprints exclude the message text, so re-wording a check never
invalidates the file.
"""

from __future__ import annotations

import json
import pathlib

from repro.analysis.findings import AnalysisReport, Severity

BASELINE_SCHEMA = 1


def load_baseline(path: str | pathlib.Path) -> set[str]:
    """Acknowledged fingerprints; a missing file is an empty baseline."""
    p = pathlib.Path(path)
    if not p.exists():
        return set()
    doc = json.loads(p.read_text())
    if doc.get("schema") != BASELINE_SCHEMA:
        raise ValueError(
            f"{p}: baseline schema {doc.get('schema')!r} != "
            f"{BASELINE_SCHEMA} — regenerate with "
            "`python -m repro.analysis --write-baseline`")
    return set(doc.get("findings", {}))


def write_baseline(path: str | pathlib.Path,
                   report: AnalysisReport) -> pathlib.Path:
    """Write every WARNING+ finding's fingerprint (INFO never gates, so
    it is never baselined)."""
    p = pathlib.Path(path)
    entries = {
        f.fingerprint: f"{f.check} {f.code} {f.subject} ({f.location})"
        for f in report.findings if f.severity >= Severity.WARNING}
    doc = {"schema": BASELINE_SCHEMA, "findings": dict(sorted(
        entries.items(), key=lambda kv: kv[1]))}
    p.write_text(json.dumps(doc, indent=1, sort_keys=False) + "\n")
    return p
