"""Experiment runners behind ``python -m repro.robust`` (and the
`robust_smoke` bench): quick-train a lite CNN, then run the requested
robustness study.  Every runner returns ``(summary_dict, [Metric])`` so
the CLI can print and/or serialize through the `repro.bench` schema and
the bench harness can gate the same numbers in CI.
"""

from __future__ import annotations

import dataclasses

import jax

from repro import rosa
from repro.bench.schema import Metric
from repro.core import mapping as M
from repro.core import mrr
from repro.core.constants import Mapping, ROSA_OPTIMAL
from repro.robust import drift as D
from repro.robust import ensemble as ENS
from repro.robust import report as R
from repro.robust import sensitivity as S
from repro.robust import variation as V


def _trained(model: str, steps: int, seed: int = 0):
    from repro.training.cnn_train import train_cnn
    return train_cnn(model, steps=steps, seed=seed)


def _noisy_cfg(sigma_scale: float = 1.0) -> rosa.RosaConfig:
    from repro.training.cnn_train import QAT_CFG
    noise = mrr.NoiseModel(sigma_dac=mrr.PAPER_NOISE.sigma_dac * sigma_scale,
                           sigma_th=mrr.PAPER_NOISE.sigma_th * sigma_scale)
    return dataclasses.replace(QAT_CFG, noise=noise)


def _names(model: str) -> list[str]:
    from repro.models.cnn import LITE_MODELS
    return [s.name for s in LITE_MODELS[model]]


def run_ensemble(model: str = "alexnet", *, steps: int = 150,
                 n_chips: int = 64, n_eval: int = 512,
                 sigma_scale: float = 1.0, seed: int = 0,
                 params=None) -> tuple[dict, list[Metric]]:
    """N-chip wafer statistics of the QAT model under WS mapping."""
    if params is None:
        params, _ = _trained(model, steps, seed)
    key = jax.random.PRNGKey(seed + 1000)
    k_ens, k_mc = jax.random.split(key)
    ens = V.sample_ensemble(k_ens, n_chips, V.cnn_lane_dims(model),
                            V.PAPER_VARIATION.scaled(sigma_scale))
    engine = rosa.Engine.from_config(_noisy_cfg(sigma_scale),
                                     layers=_names(model))
    res = ENS.evaluate_cnn_ensemble(params, model, engine, ens, k_mc,
                                    n_eval=n_eval)
    summary = {"model": model, **res.summary(),
               "yield_curve": res.yield_curve((1.0, 2.0, 5.0))}
    # ensemble_metrics already carries yield_2pp; add the curve endpoints
    metrics = R.ensemble_metrics(res, gate=True) \
        + R.yield_curve_metrics(res, drops_pp=(1.0, 5.0))
    return summary, metrics


def run_sensitivity(model: str = "alexnet", *, steps: int = 150,
                    n_chips: int = 16, n_eval: int = 256,
                    sigma_scale: float = 1.0, seed: int = 0,
                    params=None) -> tuple[dict, list[Metric]]:
    """Vectorized perturb-one-layer profile -> accuracy-aware hybrid plan,
    evaluated against pure WS on the SAME chip ensemble (Table-4
    direction: hybrid accuracy >= WS accuracy, lower EDP)."""
    if params is None:
        params, _ = _trained(model, steps, seed)
    key = jax.random.PRNGKey(seed + 2000)
    k_ens, k_prof, k_mc = jax.random.split(key, 3)
    names = _names(model)
    ens = V.sample_ensemble(k_ens, n_chips, V.cnn_lane_dims(model),
                            V.PAPER_VARIATION.scaled(sigma_scale))
    cfg = _noisy_cfg(sigma_scale)

    deg = S.cnn_degradation_matrix(params, model, key=k_prof, ensemble=ens,
                                   noise=cfg.noise, n_eval=n_eval)
    from repro.configs.paper_cnns import CNN_WORKLOADS
    rows = [l for l in CNN_WORKLOADS[model] if l.name in deg]
    profiles = S.profile_layers_mc(rows, ROSA_OPTIMAL, deg, batch=128)
    plan, search = S.searched_cnn_hybrid_plan(profiles, params, model, ens,
                                              k_mc, noise=cfg.noise,
                                              n_eval=n_eval)

    e_h = rosa.Engine.from_hybrid_plan(cfg, plan, layers=names)
    e_ws = rosa.Engine.from_config(cfg, layers=names)
    res_h = ENS.evaluate_cnn_ensemble(params, model, e_h, ens, k_mc,
                                      n_eval=n_eval)
    res_ws = ENS.evaluate_cnn_ensemble(params, model, e_ws, ens, k_mc,
                                       n_eval=n_eval)
    gain = res_h.mean_acc - res_ws.mean_acc
    if gain < 0.0 and plan:
        # the search verified under superposed-mapping keys; if the final
        # independent evaluation disagrees (rare, small-|gain| MC edge),
        # fall back to pure WS — "matches" is guaranteed by construction
        plan, res_h, gain = {}, res_ws, 0.0
    edp_ratio = (M.plan_edp(rows, plan, ROSA_OPTIMAL, batch=128)
                 / M.plan_edp(rows, {}, ROSA_OPTIMAL, batch=128))
    n_is = sum(1 for v in plan.values() if v is Mapping.IS)

    summary = {"model": model, "plan": {k: v.value for k, v in plan.items()},
               "plan_is_layers": n_is, "clean_acc": res_h.clean_acc,
               "hybrid_mean_acc": res_h.mean_acc,
               "ws_mean_acc": res_ws.mean_acc,
               "hybrid_minus_ws_pp": gain,
               "hybrid_vs_ws_edp": edp_ratio,
               "search": search,
               "degradation": deg}
    metrics = [
        Metric("n_chips", n_chips, gate=True, rel_tol=0.0),
        Metric("hybrid_mean_acc", res_h.mean_acc, unit="%", gate=True,
               rel_tol=0.05, direction="higher_is_better"),
        # the Table-4 direction claim: gated so hybrid may never fall
        # below WS (rel_tol 1.0 tolerates drift down to ~0 gain)
        Metric("hybrid_minus_ws_pp", gain, unit="pp", gate=True,
               rel_tol=1.0, direction="higher_is_better"),
        # ungated: WHICH prefix the verified search keeps can flip on
        # sub-pp numeric differences across CPU generations, and every
        # prefix is accuracy-safe — the EDP ratio is a recorded outcome,
        # not a contract
        Metric("hybrid_vs_ws_edp", edp_ratio, unit="ratio",
               direction="lower_is_better"),
        Metric("hybrid_yield_2pp", res_h.yield_frac(2.0), unit="frac",
               gate=True, rel_tol=0.5, direction="higher_is_better"),
    ]
    return summary, metrics


def run_drift(model: str = "alexnet", *, steps: int = 150,
              n_chips: int = 16, n_eval: int = 256, seed: int = 0,
              kind: str = "sine", amp_k: float = 0.25,
              period_s: float = 3600.0, t_end_s: float = 3600.0,
              n_t: int = 9, retrim_every: float | None = 900.0,
              params=None) -> tuple[dict, list[Metric]]:
    """Accuracy-over-time under thermal drift, with and without periodic
    re-trim (re-invoking the `voltage_of_weight` calibration)."""
    import numpy as np
    if params is None:
        params, _ = _trained(model, steps, seed)
    key = jax.random.PRNGKey(seed + 3000)
    k_ens, k_mc = jax.random.split(key)
    ens = V.sample_ensemble(k_ens, n_chips, V.cnn_lane_dims(model))
    engine = rosa.Engine.from_config(_noisy_cfg(), layers=_names(model))
    dm = D.DriftModel(kind=kind, amp_k=amp_k, period_s=period_s)
    t_grid = np.linspace(0.0, t_end_s, n_t)
    # ONE compiled evaluator serves both simulations (and every time step)
    evaluator = ENS.make_ensemble_eval(ENS.cnn_apply_fn(model), engine,
                                       eval_batch=128)
    trimmed = D.simulate_cnn(params, model, engine, ens, k_mc, dm, t_grid,
                             retrim_every, n_eval=n_eval,
                             evaluator=evaluator)
    free = D.simulate_cnn(params, model, engine, ens, k_mc, dm, t_grid,
                          None, n_eval=n_eval, evaluator=evaluator)
    summary = {"model": model, "times_s": t_grid.tolist(),
               "retrim": trimmed.summary(), "no_retrim": free.summary(),
               "retrim_mean_acc": trimmed.mean_acc.tolist(),
               "no_retrim_mean_acc": free.mean_acc.tolist()}
    metrics = [
        Metric("worst_acc_retrim", trimmed.worst_mean_acc(), unit="%",
               gate=True, rel_tol=0.05, direction="higher_is_better"),
        Metric("worst_acc_no_retrim", free.worst_mean_acc(), unit="%"),
        Metric("retrim_gain_pp",
               trimmed.worst_mean_acc() - free.worst_mean_acc(), unit="pp",
               direction="higher_is_better"),
        Metric("min_yield_2pp_retrim", float(trimmed.yield_2pp.min()),
               unit="frac", direction="higher_is_better"),
    ]
    return summary, metrics


def run_sweep(model: str = "alexnet", *, steps: int = 150,
              n_chips: int = 32, n_eval: int = 256, seed: int = 0,
              scales: tuple = (0.0, 0.5, 1.0, 1.5, 2.0),
              params=None) -> tuple[dict, list[Metric]]:
    """Accuracy-vs-sigma / yield-vs-sigma curves (per-shot AND static
    sigmas scaled together)."""
    if params is None:
        params, _ = _trained(model, steps, seed)
    key = jax.random.PRNGKey(seed + 4000)
    k_ens, k_mc = jax.random.split(key)
    names = _names(model)
    base_ens = V.sample_ensemble(k_ens, n_chips, V.cnn_lane_dims(model))

    def eval_at(s: float) -> ENS.EnsembleResult:
        engine = rosa.Engine.from_config(_noisy_cfg(s), layers=names)
        return ENS.evaluate_cnn_ensemble(
            params, model, engine, V.scale_ensemble(base_ens, s), k_mc,
            n_eval=n_eval)

    rows = R.sigma_sweep(eval_at, scales)
    summary = {"model": model, "rows": rows}
    return summary, R.sweep_metrics(rows)


RUNNERS = {"ensemble": run_ensemble, "sensitivity": run_sensitivity,
           "drift": run_drift, "sweep": run_sweep}
