"""Drift scenarios: plant + sensor model, the A/B harness, bench metrics.

`DriftEnv` is the physical world the controller lives in: a ground-truth
`robust.drift.DriftModel` schedule sampled per scheduler tick through the
jit-compatible `offsets_at` accessor, and a noisy temperature sensor (the
only thermal signal the controller is allowed to read — ground truth
reaches ONLY the plant-side residual injection).

`run_scenario` serves one Poisson request stream twice over the SAME
compiled drift step — uncontrolled (`DriftMonitor`) first, then
closed-loop (`AdaptiveController`) — and scores the A/B: recovered
accuracy, dropped requests, bit-exactness of every request that finished
inside the first plan epoch, and swap downtime.  Generation budgets (not
sampled EOS tokens) terminate requests, so both arms run the identical
schedule tick-for-tick and every comparison is deterministic.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.bench.schema import Metric
from repro.robust.drift import DriftModel
from repro.serve.adaptive.controller import (AdaptiveController,
                                             ControllerConfig, DriftMonitor)
from repro.serve.adaptive.probes import ProbeConfig, ProbeSet
from repro.serve.config import ServeConfig


class DriftEnv:
    """Plant + sensor.  `residual(tick, trim)` is what physically reaches
    the rings (drift minus the actuated trim); `sense(tick)` is the noisy
    reading the controller estimates from.  Ground truth never leaks to
    the decision path."""

    def __init__(self, model: DriftModel, *, tick_s: float = 30.0,
                 sensor_sigma_k: float = 0.02,
                 horizon_ticks: int = 4096, seed: int = 0):
        self.model = model
        self.tick_s = tick_s
        self.sensor_sigma_k = sensor_sigma_k
        self.horizon_ticks = horizon_ticks
        k = jax.random.PRNGKey(seed)
        self._k_walk, self._k_sense = jax.random.split(k)
        self._grid = np.arange(horizon_ticks, dtype=np.float64) * tick_s
        self._cache: dict[int, float] = {}

    def true_offset(self, tick: int) -> float:
        """Ground-truth d(t) [K] at a tick (plant side only)."""
        tick = min(int(tick), self.horizon_ticks - 1)
        if tick not in self._cache:
            self._cache[tick] = float(self.model.offsets_at(
                tick * self.tick_s, key=self._k_walk, t_grid=self._grid))
        return self._cache[tick]

    def residual(self, tick: int, trim_k: float) -> float:
        """What reaches the rings: drift minus the applied trim."""
        return self.true_offset(tick) - trim_k

    def sense(self, tick: int) -> float:
        """One temperature-sensor reading (deterministic per tick)."""
        n = float(jax.random.normal(
            jax.random.fold_in(self._k_sense, tick), ()))
        return self.true_offset(tick) + self.sensor_sigma_k * n


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """One drift-serving experiment (frozen: a scenario IS its config)."""

    arch: str = "qwen3-32b"         # smoke-config registry name
    kind: str = "sine"              # drift schedule: sine | linear | walk
    amp_k: float = 0.6              # peak thermal offset [K]
    period_ticks: float = 96.0      # schedule period/horizon in ticks
    tick_s: float = 30.0            # wall seconds one tick models
    sensor_sigma_k: float = 0.02    # temperature-sensor noise [K]
    n_requests: int = 16
    rate: float = 0.5               # Poisson arrivals per tick
    n_slots: int = 4
    max_len: int = 56
    prefill_chunk: int = 8
    variation_seed: int = 0         # pinned fabricated chip
    seed: int = 0
    probe_every: int = 2
    n_probes: int = 16
    prompt_len: int = 4
    warmup_ticks: int = 6
    force_replan_at: int | None = None


@dataclasses.dataclass
class ScenarioResult:
    """The A/B verdict plus both raw arms."""

    cfg: ScenarioConfig
    ref_agreement: float            # drift-free probe agreement (a0)
    rep_uncontrolled: object
    rep_controlled: object
    monitor: DriftMonitor
    controller: AdaptiveController
    first_action_tick: int
    sched: object = None            # the (post-swap) serving scheduler

    @property
    def recovery(self) -> float:
        """Fraction of the uncontrolled accuracy loss the controller won
        back: 1 - (lost with controller) / (lost without)."""
        lost_u = self.ref_agreement - self.monitor.mean_agreement
        lost_c = self.ref_agreement - self.controller.mean_agreement
        if lost_u <= 1e-9:
            return 1.0
        return 1.0 - lost_c / lost_u

    def dropped_requests(self, requests) -> int:
        """Requests that did not deliver their full generation budget."""
        comps = self.rep_controlled.completions
        return sum(1 for r in requests
                   if len(comps[r.rid].tokens) != r.max_new_tokens)

    def epoch_bitexact(self) -> tuple[int, bool]:
        """(n, ok): token streams of requests fully served BEFORE the
        first controller action must match the uncontrolled run's
        bit-exactly — the two arms are numerically identical until the
        controller first moves an actuator."""
        cu = self.rep_uncontrolled.completions
        cc = self.rep_controlled.completions
        n, ok = 0, True
        for rid, comp in cc.items():
            # actions land in on_tick_end, AFTER the action tick's decode
            # — a request finishing ON that tick is still pre-swap
            if 0 <= comp.done_tick <= self.first_action_tick:
                n += 1
                ok = ok and comp.tokens == cu[rid].tokens
        return n, ok

    def summary(self) -> dict:
        """One-level JSON-able scenario summary."""
        n_epoch, exact = self.epoch_bitexact()
        walls = np.asarray(self.controller.tick_wall_s or [0.0])
        return {
            "kind": self.cfg.kind, "amp_k": self.cfg.amp_k,
            "ref_agreement": self.ref_agreement,
            "uncontrolled_agreement": self.monitor.mean_agreement,
            "controlled_agreement": self.controller.mean_agreement,
            "recovery": self.recovery,
            "retrims": self.controller.retrims,
            "replans": self.controller.replans,
            "trim_updates": self.controller.trim_updates,
            "first_action_tick": self.first_action_tick,
            "epoch_requests": n_epoch, "epoch_bitexact": exact,
            "swap_downtime_ticks": max(
                [s["downtime_ticks"] for s in self.controller.swaps],
                default=0),
            "swap_wall_ms": max(
                [s["wall_s"] * 1e3 for s in self.controller.swaps],
                default=0.0),
            "p99_tick_ms": float(np.percentile(walls, 99) * 1e3),
            "final_state": self.controller.state.name,
        }


def run_scenario(cfg: ScenarioConfig = ScenarioConfig()) -> tuple:
    """Serve the stream uncontrolled then controlled; returns
    (ScenarioResult, requests)."""
    from repro import rosa
    from repro.configs import get_smoke
    from repro.serve.loadgen import poisson_requests
    from repro.serve.scheduler import Scheduler

    model_cfg = get_smoke(cfg.arch)
    scfg = ServeConfig(n_slots=cfg.n_slots, max_len=cfg.max_len,
                       prefill_chunk=cfg.prefill_chunk, seed=cfg.seed,
                       rosa=True, variation_seed=cfg.variation_seed)
    sched = Scheduler(model_cfg, scfg, init_seed=cfg.seed)
    reqs = poisson_requests(cfg.n_requests, cfg.rate,
                            vocab=model_cfg.vocab, prompt_len=(4, 8),
                            gen_len=(2, 24), seed=cfg.seed)
    env = DriftEnv(
        DriftModel(kind=cfg.kind, amp_k=cfg.amp_k,
                   period_s=cfg.period_ticks * cfg.tick_s),
        tick_s=cfg.tick_s, sensor_sigma_k=cfg.sensor_sigma_k,
        seed=cfg.seed)
    probes = ProbeSet(sched.bundle, sched.program,
                      ProbeConfig(n_probes=cfg.n_probes,
                                  prompt_len=cfg.prompt_len,
                                  seed=cfg.seed + 2024))
    ccfg = ControllerConfig(probe_every=cfg.probe_every,
                            warmup_ticks=cfg.warmup_ticks,
                            force_replan_at=cfg.force_replan_at)

    monitor = DriftMonitor(sched, env, probes, ccfg)
    rep_u = sched.run(reqs, hook=monitor)

    controller = AdaptiveController(
        sched, env, probes, ccfg,
        plan_cache=rosa.PlanCache(max_entries=256))
    rep_c = sched.run(reqs, hook=controller)

    res = ScenarioResult(cfg=cfg, ref_agreement=monitor.ref_agreement,
                         rep_uncontrolled=rep_u, rep_controlled=rep_c,
                         monitor=monitor, controller=controller,
                         first_action_tick=controller.first_action_tick,
                         sched=sched)
    return res, reqs


def drift_serve_metrics(quick: bool = True) -> tuple:
    """The gated `drift_serve` bench: sine drift, forced mid-stream
    replan; returns (ScenarioResult, [Metric]).

    Every gated number is deterministic: seeded drift/noise/requests,
    budget-driven termination, tick-unit accounting."""
    cfg = ScenarioConfig(force_replan_at=30) if not quick else \
        ScenarioConfig(n_requests=12, force_replan_at=30)
    res, reqs = run_scenario(cfg)
    s = res.summary()
    n_epoch, exact = res.epoch_bitexact()
    metrics = [
        Metric("recovery_frac", round(res.recovery, 4), "frac",
               gate=True, rel_tol=0.1, direction="higher_is_better"),
        Metric("recovery_ge_80pct", int(res.recovery >= 0.8), "bool",
               gate=True, rel_tol=0.0, direction="higher_is_better"),
        Metric("dropped_requests", res.dropped_requests(reqs), "requests",
               gate=True, rel_tol=0.0, direction="lower_is_better"),
        Metric("epoch_bitexact", int(exact), "bool",
               gate=True, rel_tol=0.0, direction="higher_is_better"),
        Metric("epoch_requests", n_epoch, "requests"),
        Metric("swap_downtime_ticks", s["swap_downtime_ticks"], "ticks",
               gate=True, rel_tol=0.0, direction="lower_is_better"),
        Metric("retrims", res.controller.retrims, "count",
               gate=True, rel_tol=0.0),
        Metric("replans", res.controller.replans, "count",
               gate=True, rel_tol=0.0),
        Metric("trim_updates", res.controller.trim_updates, "count"),
        Metric("uncontrolled_agreement",
               round(res.monitor.mean_agreement, 4), "frac",
               gate=True, rel_tol=0.05, direction="higher_is_better"),
        Metric("controlled_agreement",
               round(res.controller.mean_agreement, 4), "frac",
               gate=True, rel_tol=0.05, direction="higher_is_better"),
        Metric("ref_agreement", round(res.ref_agreement, 4), "frac"),
        Metric("swap_wall_ms", round(s["swap_wall_ms"], 2), "ms"),
        Metric("p99_tick_ms", round(s["p99_tick_ms"], 2), "ms"),
    ]
    return res, metrics
