"""Pallas TPU megakernel: the fused ROSA analog hot path.

One `pallas_call` per (bm, bn) output tile performs what the composed
`rosa.backends` pipeline lowers as four separate device ops with HBM
round-trips between them:

    quantize -> mrr_transfer realization (noise + static variation)
             -> per-plane OSA shift-and-add -> f32 accumulate -> dequantize

The fusion is paper-faithful in the same sense the hardware is: on the
photonic chip the voltage->weight transfer, the splitter/ODL shift ladder
and the photodetector accumulate are ONE analog pipeline — intermediate
"tensors" never exist.  Here they never leave VMEM.

Operand layout (all f32, padded to block multiples by ops.py):

    x       (M, K)        activations
    w       (K, N)        weights
    gains   (T,)          OSA slot-gain ladder (ideal: 2^(radix_bits*t))
    sx      (M, 3)        per-row scale columns [sxd, sxa, s2]:
                          digital full-scale, analog (per-row) full-scale,
                          requantization full-scale (per-tensor scales are
                          broadcast into the column by the wrapper)
    gg      (3,)          [gate, mgate, sw] — the traced analog/digital
                          blend gate, the traced WS/IS mapping selector,
                          and the per-tensor weight full-scale
    x_off   3 x (M, K)    folded noise+variation offsets for the x side
                          (v_off = sigma_dac*eps + dv, t_off = sigma_th*eps
                          + ddt, l_off = dlam) — present iff realize_x
    w_off   3 x (K, N)    same for the w side — present iff realize_w

Gates ride as OPERANDS, not static params: sweeping `gate`/`mgate` (the
PR 7 gated evaluators) revisits the same compiled kernel, no retrace.
Static specialization covers only trace-stable structure: mode, which
sides realize, and whether each gate exists at all.

Grid is (M/bm, N/bn, K/bk) with K innermost sequential; the f32
accumulator lives in VMEM scratch and the output tile is written once at
the last K step (the photodetector's one-conversion-per-output).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core import mrr
from repro.kernels import tpu_compiler_params


def _realize(wn, v_off, t_off, l_off, p: mrr.MRRParams,
             t_hi: float, t_lo: float):
    """VMEM-resident analog realization: normalized target -> programming
    voltage (closed-form inverse) -> noisy forward chain -> realized weight.

    Offset form of core.mrr.realize_weights: the per-shot Gaussian draws
    and the chip's StaticVariation arrive pre-folded into three additive
    offsets at exactly the insertion points of mrr.weight_of_voltage.
    """
    # ---- inverse: target weight -> programming voltage (Eqs. 3-8 inverted)
    wq = jnp.clip(wn, p.q_min, p.q_max)
    td = t_lo + (wq - p.q_min) / p.q_rng * (t_hi - t_lo)
    tdrop = 0.5 * (td + 1.0)
    det = p.gamma * jnp.sqrt(jnp.maximum(1.0 / tdrop - 1.0, 0.0))
    lam = p.lambda_ref + det
    dl = lam - p.lambda_0
    u = dl / p.lambda_0
    dt = p.n_eff * u / (p.beta * (1.0 - u))
    p_mw = dt / p.r_thermal
    v2 = p_mw / (p.kappa * 1e3) * p.r_heater
    v = jnp.clip(jnp.sqrt(jnp.maximum(v2, 0.0)), p.v_min, p.v_max)
    # ---- forward with folded noise/variation offsets
    v = v + v_off
    dtn = (p.kappa * (v * v / p.r_heater) * 1e3) * p.r_thermal + t_off
    bdt = p.beta * dtn
    # small detuning terms accumulate BEFORE the ~1538 nm resonance
    # constant (same f32-rounding discipline as mrr.weight_of_voltage)
    lam2 = p.lambda_0 + (p.lambda_0 * bdt / (p.n_eff + bdt) + l_off)
    detu = lam2 - p.lambda_ref
    g2 = p.gamma * p.gamma
    td2 = 2.0 * g2 / (detu * detu + g2) - 1.0
    return p.q_min + p.q_rng * (td2 - t_lo) / (t_hi - t_lo)


def _kernel(*refs, analog: bool, n_planes: int, radix_bits: int, qmax: int,
            realize_x: bool, realize_w: bool, use_gate: bool,
            use_mgate: bool, k_steps: int, k_real: int, bk: int,
            p: mrr.MRRParams, t_hi: float, t_lo: float):
    """Grid = (M/bm, N/bn, K/bk); K innermost (sequential accumulation)."""
    it = iter(refs)
    x_ref, w_ref, g_ref, sx_ref, gg_ref = (next(it) for _ in range(5))
    x_off = tuple(next(it) for _ in range(3)) if realize_x else None
    w_off = tuple(next(it) for _ in range(3)) if realize_w else None
    o_ref, acc_ref = next(it), next(it)

    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    sx = sx_ref[...]
    sxd, sxa, s2 = sx[:, 0:1], sx[:, 1:2], sx[:, 2:3]      # (bm, 1) each
    gg = gg_ref[...]
    gate, mgate, sw = gg[0], gg[1], gg[2]
    qf = jnp.float32(qmax)

    # ---- weight side: one normalized grid serves the digital path AND the
    # analog chain input (fake_quant(w/sw) lands on the same codes)
    wn = jnp.clip(jnp.round(w / sw * qf), -qf, qf) * (1.0 / qf)
    if realize_w:
        w_an = _realize(wn, *w_off_vals(w_off), p=p, t_hi=t_hi, t_lo=t_lo)
        w_ws = wn + gate * (w_an - wn) if use_gate else w_an
    else:
        w_ws = wn
    w_eff = (1.0 - mgate) * w_ws + mgate * wn if use_mgate else w_ws
    if realize_w and k_real % bk:
        # the composed path realizes BEFORE zero-padding; in-tile, the MRR
        # chain maps a padded 0 target to a nonzero realized weight, so
        # padded K lanes must be masked out of the contraction explicitly
        k_ids = k_idx * bk + jax.lax.broadcasted_iota(
            jnp.int32, w_eff.shape, 0)
        w_eff = jnp.where(k_ids < k_real, w_eff, 0.0)

    # ---- activation side: digital EO path at the digital full-scale,
    # analog realization at the per-row analog full-scale, blended at
    # ACTUAL scale exactly like rosa.backends._analog_operand
    x_dig = jnp.clip(jnp.round(x / sxd * qf), -qf, qf) * (sxd / qf)
    if realize_x:
        xn = jnp.clip(jnp.round(x / sxa * qf), -qf, qf) * (1.0 / qf)
        x_an = _realize(xn, *x_off_vals(x_off), p=p, t_hi=t_hi,
                        t_lo=t_lo) * sxa
        x_is = x_dig + gate * (x_an - x_dig) if use_gate else x_an
    else:
        x_is = x_dig
    x_eff = (1.0 - mgate) * x_dig + mgate * x_is if use_mgate else x_is
    if realize_x and k_real % bk:
        # same padded-lane masking for the activation side (columns are K)
        k_ids = k_idx * bk + jax.lax.broadcasted_iota(
            jnp.int32, x_eff.shape, 1)
        x_eff = jnp.where(k_ids < k_real, x_eff, 0.0)

    if analog:
        # single-shot analog readout: no digit planes, direct MXU contract
        # of the normalized operands; scales fold back at the flush
        acc_ref[...] += jnp.dot(x_eff * (1.0 / s2), w_eff,
                                preferred_element_type=jnp.float32)
    else:
        # requantize the conditioned activations (the DAC feeding the EO
        # modulators) and hoist the OSA slot recombination before ONE MXU
        # pass — same algebra as kernels/osa_matmul's fused mode
        q2 = jnp.clip(jnp.round(x_eff / s2 * qf), -qf, qf)
        sign = jnp.sign(q2)
        mag = jnp.abs(q2).astype(jnp.int32)
        mask = (1 << radix_bits) - 1
        g = g_ref[...]
        x_rec = jnp.zeros_like(q2)
        for t in range(n_planes):
            d = (mag >> (radix_bits * t)) & mask
            x_rec = x_rec + g[t] * (sign * d.astype(q2.dtype))
        acc_ref[...] += jnp.dot(x_rec, w_eff,
                                preferred_element_type=jnp.float32)

    @pl.when(k_idx == k_steps - 1)
    def _flush():
        # electronic post-ADC rescale: per-row requant scale x weight
        # full-scale (MIXED folds the extra 1/qmax of the integer planes)
        if analog:
            o_ref[...] = acc_ref[...] * (s2 * sw)
        else:
            o_ref[...] = acc_ref[...] * (s2 * (sw / qf))


def x_off_vals(x_off):
    """Load the three x-side offset blocks (v_off, t_off, l_off)."""
    return tuple(r[...] for r in x_off)


def w_off_vals(w_off):
    """Load the three w-side offset blocks (v_off, t_off, l_off)."""
    return tuple(r[...] for r in w_off)


@functools.partial(jax.jit, static_argnames=(
    "analog", "n_planes", "radix_bits", "qmax", "realize_x", "realize_w",
    "use_gate", "use_mgate", "k_real", "p", "bm", "bn", "bk", "interpret"))
def rosa_fused_pallas(x: jax.Array, w: jax.Array, gains: jax.Array,
                      sx: jax.Array, gg: jax.Array,
                      x_off: "tuple[jax.Array, ...] | None" = None,
                      w_off: "tuple[jax.Array, ...] | None" = None,
                      *, analog: bool = False, n_planes: int = 7,
                      radix_bits: int = 1, qmax: int = 127,
                      realize_x: bool = False, realize_w: bool = True,
                      use_gate: bool = False, use_mgate: bool = False,
                      k_real: int = 0,
                      p: mrr.MRRParams = mrr.DEFAULT_PARAMS,
                      bm: int = 128, bn: int = 128, bk: int = 128,
                      interpret: bool = False) -> jax.Array:
    """Fused quantize+realize+OSA+accumulate+dequantize GEMM.

    M, K, N must be multiples of (bm, bk, bn) — ops.py pads.  `x_off` /
    `w_off` must be present exactly when `realize_x` / `realize_w`.
    `k_real` is the unpadded reduction length (padded K lanes must not
    realize — see the masking comment in `_kernel`); 0 means K is exact.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    assert (x_off is not None) == realize_x
    assert (w_off is not None) == realize_w
    k_steps = k // bk

    t_hi, t_lo = mrr.transmission_endpoints_py(p)
    kernel = functools.partial(
        _kernel, analog=analog, n_planes=n_planes, radix_bits=radix_bits,
        qmax=qmax, realize_x=realize_x, realize_w=realize_w,
        use_gate=use_gate, use_mgate=use_mgate, k_steps=k_steps,
        k_real=k_real, bk=bk, p=p, t_hi=t_hi, t_lo=t_lo)

    x_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
    w_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
    in_specs = [
        x_spec,
        w_spec,
        pl.BlockSpec((gains.shape[0],), lambda i, j, kk: (0,)),
        pl.BlockSpec((bm, 3), lambda i, j, kk: (i, 0)),
        pl.BlockSpec((3,), lambda i, j, kk: (0,)),
    ]
    operands = [x, w, gains, sx, gg]
    if realize_x:
        in_specs += [x_spec] * 3
        operands += list(x_off)
    if realize_w:
        in_specs += [w_spec] * 3
        operands += list(w_off)

    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
