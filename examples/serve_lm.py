"""LM serving demo: a Poisson request stream through continuous batching.

Uses the reduced zamba2 (hybrid SSM + shared-attention) config so the
example exercises the most interesting cache machinery: per-group shared
KV caches + SSD states + conv states, admitted and evicted slot-by-slot.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import subprocess
import sys

if __name__ == "__main__":
    raise SystemExit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "zamba2-1.2b",
         "--smoke", "--requests", "12", "--rate", "1.0", "--n-slots", "2",
         "--max-len", "48", "--gen-range", "2", "24",
         "--temperature", "0.7"]))
