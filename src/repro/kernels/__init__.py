# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.


def tpu_compiler_params(**kwargs):
    """Pallas TPU CompilerParams across the jax rename (TPUCompilerParams
    in older releases).  Raises a descriptive error if neither exists."""
    import jax.experimental.pallas.tpu as pltpu
    cls = getattr(pltpu, "CompilerParams",
                  getattr(pltpu, "TPUCompilerParams", None))
    if cls is None:
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams; unsupported jax version")
    return cls(**kwargs)
