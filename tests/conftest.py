"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — tests must see
the plain 1-device CPU; only launch/dryrun.py forces 512 devices."""

import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
